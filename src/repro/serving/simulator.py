"""Discrete-event serving-cluster simulator (the simulated data plane).

Drives the *same* Gimbal control plane (scheduler, queue policy, profiler,
placement manager, coordinator) as the real engine, against the roofline
cost model. Supports every paper configuration: vLLM-like baseline
(round-robin/request-count + FCFS + EPLB), MoETuner-like (static offline
affinity placement), Sem-MoE-like (oracle static placement + work-balanced
routing), and all Gimbal ablations (DP / EP / All-no-collab / All).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.core.coordinator import CoordinatorConfig, GimbalCoordinator
from repro.core.forecast import ForecastConfig, PrefetchConfig
from repro.core.placement import PlacementConfig, default_distance_matrix, \
    greedy_layer_placement
from repro.core.scheduler import (BaselineScheduler, GimbalScheduler,
                                  SchedulerConfig)
from repro.core.traces import TraceTable
from repro.serving.costmodel import CostModelConfig, EngineCostModel
from repro.serving.engine import DPEngine, EngineConfig
from repro.serving.request import Request
from repro.serving.routing_sim import SourceExpertTraffic


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """One serving-system variant (maps to the paper's baselines/ablations)."""

    name: str = "gimbal"
    dp_scheduler: str = "gimbal"        # gimbal | round_robin | least_requests | oracle
    queue_policy: str = "sjf_aging"     # sjf_aging | fcfs
    ep_policy: str = "gimbal"           # gimbal | eplb | static_affinity | static_ilp | none
    feedback: bool = True               # MoE pressure -> DP scheduler
    placement_cfg: Optional[PlacementConfig] = None
    redundant_slots: int = 0            # beyond-paper: hot-expert replicas
    n_engines: int = 2
    n_ranks: int = 4
    n_moe_layers: int = 48
    n_experts: int = 128
    top_k: int = 8
    trace_interval_s: float = 0.05      # async engine-stats reporting period
    window_tokens: int = 40_000
    # ---- predictive placement (core/forecast.py): rebalance against the
    # forecast next window; prefetch stages the expert-weight copy off the
    # serving path and flips only once it lands (no migration stall)
    predictive: bool = False
    prefetch: bool = False
    forecast_cfg: Optional[ForecastConfig] = None
    prefetch_cfg: Optional[PrefetchConfig] = None
    # routing non-stationarity fed to SourceExpertTraffic (zipf_shift):
    # hot-expert set fully rotates every N routed tokens (0 = stationary)
    routing_shift_tokens: int = 0
    routing_shift_roll: int = 0         # 0 -> E // 8


PAPER_SYSTEMS: Dict[str, SystemConfig] = {
    "vllm": SystemConfig(name="vllm", dp_scheduler="least_requests",
                         queue_policy="fcfs", ep_policy="eplb",
                         feedback=False),
    "moetuner": SystemConfig(name="moetuner", dp_scheduler="least_requests",
                             queue_policy="fcfs",
                             ep_policy="static_affinity", feedback=False),
    "semmoe": SystemConfig(name="semmoe", dp_scheduler="oracle",
                           queue_policy="fcfs", ep_policy="static_ilp",
                           feedback=False),
    "gimbal": SystemConfig(name="gimbal"),
    "gimbal_dp": SystemConfig(name="gimbal_dp", ep_policy="eplb",
                              feedback=False),
    "gimbal_ep": SystemConfig(name="gimbal_ep", dp_scheduler="least_requests",
                              queue_policy="fcfs", feedback=False),
    "gimbal_nocollab": SystemConfig(name="gimbal_nocollab", feedback=False),
    "gimbal_uncalibrated": SystemConfig(
        name="gimbal_uncalibrated",
        placement_cfg=PlacementConfig.uncalibrated()),
    # beyond-paper: Gimbal + 4 redundant hot-expert replicas per layer
    "gimbal_replicated": SystemConfig(name="gimbal_replicated",
                                      redundant_slots=4),
    # beyond-paper: predictive placement — forecast next-window traffic,
    # rebalance toward it; "gimbal_forecast" migrates synchronously (the
    # prediction-only ablation), "gimbal_predictive" additionally hides
    # the migration behind an async expert-weight prefetch
    "gimbal_forecast": SystemConfig(name="gimbal_forecast", predictive=True),
    "gimbal_predictive": SystemConfig(name="gimbal_predictive",
                                      predictive=True, prefetch=True),
}


class EPLBPlacementPolicy:
    """Aggregate-load-only rebalancing (DeepSeek EPLB style): sort experts by
    load, snake-assign across ranks. Ignores the A matrix entirely.
    Rearranges only when the current per-rank imbalance crosses a threshold
    (vLLM-style rearrangement trigger)."""

    def __init__(self, manager, threshold: float = 1.15):
        self.manager = manager
        self.threshold = threshold

    def update(self, B, A):
        loads = self.manager.per_rank_load(B.astype(np.float64))  # (L, G)
        tot = loads.sum()
        if tot > 0:
            lsum = loads.sum(axis=1)
            valid = lsum > 0
            per_layer = loads[valid].max(axis=1) / (
                lsum[valid] / loads.shape[1])
            imb = float(np.average(per_layer,
                                   weights=np.maximum(lsum[valid], 1)))
            if imb < self.threshold:
                return []
        plan = []
        G = self.manager.G
        for l in range(B.shape[0]):
            if B[l].sum() == 0:
                continue
            order = np.argsort(-B[l])
            new = np.zeros_like(self.manager.assign[l])
            for i, e in enumerate(order):
                cyc = i % (2 * G)
                new[e] = cyc if cyc < G else 2 * G - 1 - cyc  # snake
            moved = np.flatnonzero(new != self.manager.assign[l])
            for e in moved:
                plan.append((l, int(e), int(self.manager.assign[l, e]),
                             int(new[e])))
            self.manager.assign[l] = new
        if plan:
            self.manager.n_rebalances += 1
            self.manager.n_migrations += len(plan)
        return plan


@dataclasses.dataclass
class SimResult:
    name: str
    requests: List[Request] = dataclasses.field(default_factory=list)
    duration_s: float = 0.0
    signals: Dict = dataclasses.field(default_factory=dict)
    # the engines the run used — telemetry source for the scenario
    # invariant pack (not part of the serialized result)
    engines: Optional[List] = dataclasses.field(default=None, repr=False)

    def _arr(self, fn):
        done = [r for r in self.requests if r.finish_time > 0]
        return np.asarray([fn(r) for r in done]) if done else np.zeros(1)

    @property
    def mean_ttft(self):
        return float(self._arr(lambda r: r.ttft).mean())

    @property
    def p99_ttft(self):
        return float(np.percentile(self._arr(lambda r: r.ttft), 99))

    @property
    def mean_tpot(self):
        a = self._arr(lambda r: r.tpot)
        return float(a[a > 0].mean()) if (a > 0).any() else 0.0

    @property
    def mean_e2e(self):
        return float(self._arr(lambda r: r.e2e).mean())

    @property
    def throughput(self):
        n_done = sum(1 for r in self.requests if r.finish_time > 0)
        return n_done / max(self.duration_s, 1e-9)


def simulate(requests: List[Request], system: SystemConfig, *,
             cost_cfg: Optional[CostModelConfig] = None,
             engine_cfg: Optional[EngineConfig] = None,
             traffic_seed: int = 0, horizon_s: float = 3600.0,
             metrics=None) -> SimResult:
    """``metrics`` (a ``core.metrics.StreamingMetrics``) is fed every
    non-error finish as it happens, so 10^6-request runs get streaming
    p50/p99 without holding raw latency arrays."""
    sc = system
    cost = EngineCostModel(cost_cfg or CostModelConfig(top_k=sc.top_k))
    ecfg = engine_cfg or EngineConfig()
    ecfg = dataclasses.replace(ecfg, queue_policy=sc.queue_policy)

    traffic = SourceExpertTraffic(sc.n_moe_layers, sc.n_experts, sc.n_engines,
                                  seed=traffic_seed,
                                  shift_every_tokens=sc.routing_shift_tokens,
                                  shift_roll=sc.routing_shift_roll)
    engines = [DPEngine(i, ecfg, cost, traffic, sc.top_k)
               for i in range(sc.n_engines)]
    table = TraceTable(range(sc.n_engines))

    # ---- DP scheduler
    if sc.dp_scheduler == "gimbal":
        sched = GimbalScheduler(table)
    elif sc.dp_scheduler in ("round_robin", "least_requests"):
        sched = BaselineScheduler(table, sc.dp_scheduler)
    else:
        sched = None  # oracle handled inline

    # ---- EP placement policy
    D = default_distance_matrix(sc.n_engines, sc.n_ranks)
    coord = GimbalCoordinator(
        sc.n_moe_layers, sc.n_experts, sc.n_ranks, sc.n_engines,
        cfg=CoordinatorConfig(window_tokens=sc.window_tokens,
                              feedback=sc.feedback,
                              rebalance=sc.ep_policy in
                              ("gimbal", "eplb"),
                              predictive=sc.predictive,
                              prefetch=sc.prefetch,
                              forecast_cfg=sc.forecast_cfg,
                              prefetch_cfg=sc.prefetch_cfg),
        placement_cfg=sc.placement_cfg, D=D,
        redundant_slots=sc.redundant_slots)
    eplb = EPLBPlacementPolicy(coord.placement) if sc.ep_policy == "eplb" \
        else None

    if sc.ep_policy in ("static_affinity", "static_ilp"):
        # offline profile: captured on a *different* workload window, so it
        # holds the persistent routing structure but misses the live mix —
        # the staleness the paper identifies in MoETuner/Sem-MoE (§2.3).
        stale = SourceExpertTraffic(sc.n_moe_layers, sc.n_experts,
                                    sc.n_engines, seed=traffic_seed + 777)
        pref_off = 0.35 * traffic.pref + 0.65 * stale.pref
        B_off = pref_off.sum(axis=1) * 1e6              # (L, E)
        A_off = pref_off * 1e6                          # (L, S, E)
        pc = coord.placement.cfg
        for l in range(sc.n_moe_layers):
            if sc.ep_policy == "static_affinity":
                Azero = np.zeros((sc.n_engines, sc.n_experts))
                coord.placement.assign[l] = greedy_layer_placement(
                    B_off[l], Azero, D, None,
                    PlacementConfig(alpha=0.0, beta=1.0, gamma=0.0))
            else:
                coord.placement.assign[l] = greedy_layer_placement(
                    B_off[l], A_off[l], D, None,
                    PlacementConfig(alpha=1.0, beta=pc.beta, gamma=0.0))

    # oracle (Sem-MoE) dispatch: balances total known work across engines
    oracle_load = np.zeros(sc.n_engines)

    # ---- event loop ------------------------------------------------------
    # events: (time, seq, kind, payload)
    events = []
    seq = 0
    for r in requests:
        heapq.heappush(events, (r.arrival_time, seq, "arrival", r))
        seq += 1
    heapq.heappush(events, (0.0, seq, "trace", None))
    seq += 1
    engine_busy_until = [0.0] * sc.n_engines
    engine_scheduled = [False] * sc.n_engines
    migration_until = 0.0
    now = 0.0
    samples = {"running": [], "kv": []}   # Fig. 12 runtime signals

    def refresh_backend_signals():
        load = coord._last_rank_load                     # (L, G)
        tot = load.sum()
        # Execution is per-MoE-layer: every layer's all-to-all completes when
        # its hottest rank finishes, so the step stretch is the load-weighted
        # mean over layers of (max_g / mean_g) — a GLOBAL slowdown shared by
        # the co-located engines (DP+TP+EP share chips, paper §2.2.3).
        if tot > 0:
            lsum = load.sum(axis=1)                      # (L,)
            valid = lsum > 0
            per_layer = np.ones(load.shape[0])
            per_layer[valid] = load[valid].max(axis=1) / (
                lsum[valid] / sc.n_ranks)
            imb = float(np.average(per_layer, weights=np.maximum(lsum, 1)))
        else:
            imb = 1.0
        for e in engines:
            # global per-layer imbalance + local co-located-rank contention
            # (DP+TP+EP share chips: hot local ranks steal the co-located
            # engine's compute, paper §2.2.3)
            cont = coord.engine_contention(e.engine_id)
            e.moe_imbalance = max(imb, 1.0) + 1.0 * cont
            e.moe_pressure = coord.engine_moe_pressure(e.engine_id)
        # remote fraction under current placement (per engine/source);
        # with replication, traffic routes to the NEAREST copy
        for e in engines:
            pref = traffic.pref[:, e.engine_id, :]       # (L, E)
            remote = 0.0
            for l in range(sc.n_moe_layers):
                dist = D[e.engine_id, coord.placement.assign[l]].copy()
                if coord.placement.R > 0:
                    for i in range(coord.placement.R):
                        ex = coord.placement.replica_expert[l, i]
                        g = coord.placement.replica_rank[l, i]
                        if ex >= 0 and g >= 0:
                            dist[ex] = min(dist[ex], D[e.engine_id, g])
                remote += float(pref[l][dist > 0].sum())
            e.remote_frac = remote / sc.n_moe_layers

    def kick(eng_id: int, t: float):
        nonlocal seq
        if not engine_scheduled[eng_id]:
            engine_scheduled[eng_id] = True
            heapq.heappush(events, (max(t, engine_busy_until[eng_id],
                                        migration_until), seq, "step",
                            eng_id))
            seq += 1

    fin_seen = [0] * sc.n_engines     # per-engine drained-finish watermark

    def drain_finishes():
        if metrics is None:
            return
        for i, e in enumerate(engines):
            fl = e.finished
            for r in fl[fin_seen[i]:]:
                if not r.error:
                    metrics.observe_request(r)
            fin_seen[i] = len(fl)

    refresh_backend_signals()
    while events:
        now, _, kind, payload = heapq.heappop(events)
        if now > horizon_s:
            break
        if kind == "arrival":
            r: Request = payload
            if sc.dp_scheduler == "oracle":
                work = r.prompt_len + 4.0 * r.max_new_tokens
                eid = int(np.argmin(oracle_load))
                oracle_load[eid] += work
            else:
                eid = sched.select_engine(r.prompt_len, now,
                                          prompt_tokens=r.prompt_tokens)
                # the simulator never excludes engines, so a None (empty
                # fleet) return cannot happen on a well-formed SystemConfig
                assert eid is not None, "simulator fleet is empty"
            engines[eid].enqueue(r, now)
            kick(eid, now)
        elif kind == "trace":
            for e in engines:
                table.report(e.trace(now, full_prefix_summary=table.
                                     needs_resync(e.engine_id)), now=now)
                if sched is not None and hasattr(sched, "on_trace_refresh"):
                    sched.on_trace_refresh(e.engine_id)
            if any(e.has_work for e in engines):
                samples["running"].append(
                    np.mean([len(e.running) for e in engines]))
                samples["kv"].append(np.mean([e.pool.usage for e in engines]))
            if any(e.has_work for e in engines) or events:
                heapq.heappush(events, (now + sc.trace_interval_s, seq,
                                        "trace", None))
                seq += 1
        elif kind == "step":
            eid = payload
            engine_scheduled[eid] = False
            if now < migration_until:
                kick(eid, migration_until)
                continue
            e = engines[eid]
            dur, routed, info = e.step(now)
            if routed is not None:
                coord.profiler.record_step(
                    routed, routed[:, None, :] *
                    (np.arange(sc.n_engines) == eid)[None, :, None],
                    n_tokens=info.get("prefill_tokens", 0)
                    + info.get("decode_tokens", 0))
                if sc.ep_policy == "eplb" and \
                        coord.profiler.window_tokens >= sc.window_tokens:
                    B, A = coord.profiler.snapshot(reset=True)
                    plan = eplb.update(B, A)
                    coord._last_rank_load = coord.placement.per_rank_load(
                        B.astype(np.float64))
                    if plan:
                        migration_until = now + dur + \
                            coord.migration_duration(len(plan))
                        coord._migrated_once = True
                    refresh_backend_signals()
                elif sc.ep_policy == "gimbal":
                    migrated, mdur = coord.maybe_rebalance(now)
                    if migrated:
                        migration_until = now + dur + mdur
                    if migrated or coord.profiler.window_tokens == 0:
                        refresh_backend_signals()
                elif coord.profiler.window_tokens >= sc.window_tokens:
                    # static policies still track load for pressure signals
                    B, _ = coord.profiler.snapshot(reset=True)
                    coord._last_rank_load = coord.placement.per_rank_load(
                        B.astype(np.float64))
                    refresh_backend_signals()
            if sc.ep_policy == "gimbal" and coord.poll_prefetch(now):
                # staged weights landed: pointer flip off the serving path
                # (flip_s > 0 models a non-free pointer swap)
                if coord.cfg.flip_s > 0:
                    migration_until = max(migration_until,
                                          now + coord.cfg.flip_s)
                refresh_backend_signals()
            drain_finishes()
            if dur > 0:
                engine_busy_until[eid] = now + dur
                kick(eid, now + dur)
            elif e.has_work:
                kick(eid, now + 0.001)

    drain_finishes()
    res = SimResult(name=sc.name, requests=requests, duration_s=now,
                    engines=engines)
    res.signals = {
        "avg_running": float(np.mean(samples["running"]))
        if samples["running"] else 0.0,
        "kv_usage": float(np.mean(samples["kv"])) if samples["kv"] else 0.0,
        "prompt_tput_gap": _prompt_tput_gap(engines),
        "migrations": coord.placement.n_migrations,
        "decisions": getattr(sched, "decisions", {}),
        "preemptions": sum(r.n_preemptions for r in requests),
        # StepPlanner packing telemetry, comparable with the real plane's
        "prefill_dispatches": sum(e.prefill_dispatches for e in engines),
        "prefill_lanes_per_dispatch": (
            sum(e.prefill_lanes_total for e in engines)
            / max(sum(e.prefill_dispatches for e in engines), 1)),
        "routing_shifts": traffic.n_shifts,
    }
    res.signals.update(coord.placement_signals())
    if metrics is not None:
        res.signals["metrics"] = metrics.snapshot()
    return res


def _prompt_tput_gap(engines) -> float:
    """Cross-engine prompt-throughput gap (tokens/s), the Fig. 12 signal."""
    rates = [e.total_prefill_tokens / max(e.busy_time, 1e-9) for e in engines]
    return float(max(rates) - min(rates)) if len(rates) > 1 else 0.0
