"""Synthetic source-dependent expert-routing traffic (simulated data plane).

Reproduces the two routing phenomena the paper measures (Fig. 3/4): skewed
expert popularity (Zipf hotspots per layer) and *source-dependent* traffic
(each DP source tilts toward its own expert subset, drifting slowly over
time). The real data plane gets these statistics from actual router outputs;
the simulator draws from this model.
"""
from __future__ import annotations

import numpy as np


class SourceExpertTraffic:
    def __init__(self, n_layers: int, n_experts: int, n_sources: int, *,
                 zipf_a: float = 1.4, source_tilt: float = 4.0,
                 drift: float = 0.02, seed: int = 0):
        self.L, self.E, self.S = n_layers, n_experts, n_sources
        self.drift = drift
        rng = np.random.default_rng(seed)
        self._rng = rng
        base = (1.0 / np.arange(1, n_experts + 1) ** zipf_a)
        self.pref = np.zeros((n_layers, n_sources, n_experts))
        for l in range(n_layers):
            pop = rng.permutation(base)             # layer-wise hotspots
            for s in range(n_sources):
                tilt = np.ones(n_experts)
                fav = rng.choice(n_experts, size=max(n_experts // 8, 1),
                                 replace=False)
                tilt[fav] *= source_tilt            # source-favored experts
                p = pop * tilt
                self.pref[l, s] = p / p.sum()

    def maybe_drift(self) -> None:
        """Slow routing drift (what makes static placements go stale)."""
        if self._rng.random() < self.drift:
            l = self._rng.integers(0, self.L)
            s = self._rng.integers(0, self.S)
            p = self.pref[l, s]
            shift = self._rng.permutation(p) * 0.3 + p * 0.7
            self.pref[l, s] = shift / shift.sum()

    def sample_counts(self, source: int, tokens: int, top_k: int
                      ) -> np.ndarray:
        """(L, E) expected routed counts (+Poisson noise) for one step."""
        lam = self.pref[:, source, :] * (tokens * top_k)
        return self._rng.poisson(lam).astype(np.int64)
