"""Synthetic source-dependent expert-routing traffic (simulated data plane).

Reproduces the two routing phenomena the paper measures (Fig. 3/4): skewed
expert popularity (Zipf hotspots per layer) and *source-dependent* traffic
(each DP source tilts toward its own expert subset, drifting slowly over
time). ``shift_every_tokens`` adds scheduled routing NON-stationarity: the
hot-expert set rotates continuously along the expert axis (the zipf_shift
scenario's drifting skew, which predictive placement forecasts ahead of).
The real data plane gets these statistics from actual router outputs; the
simulator draws from this model.
"""
from __future__ import annotations

import numpy as np


class SourceExpertTraffic:
    def __init__(self, n_layers: int, n_experts: int, n_sources: int, *,
                 zipf_a: float = 1.4, source_tilt: float = 4.0,
                 drift: float = 0.02, seed: int = 0,
                 shift_every_tokens: int = 0, shift_roll: int = 0):
        self.L, self.E, self.S = n_layers, n_experts, n_sources
        self.drift = drift
        rng = np.random.default_rng(seed)
        self._rng = rng
        base = (1.0 / np.arange(1, n_experts + 1) ** zipf_a)
        self.pref = np.zeros((n_layers, n_sources, n_experts))
        for l in range(n_layers):
            pop = rng.permutation(base)             # layer-wise hotspots
            for s in range(n_sources):
                tilt = np.ones(n_experts)
                fav = rng.choice(n_experts, size=max(n_experts // 8, 1),
                                 replace=False)
                tilt[fav] *= source_tilt            # source-favored experts
                p = pop * tilt
                self.pref[l, s] = p / p.sum()
        # ---- routing non-stationarity (zipf_shift): the hot-expert set
        # rotates CONTINUOUSLY — every shift_every_tokens sampled, each
        # preference row has fully blended toward its roll-by-shift_roll
        # image, so hotspots drift along the expert axis at a steady,
        # seeded rate. This is the drifting-skew regime where reactive
        # placement always lags one window behind the traffic and a
        # short-horizon forecaster can aim ahead of it.
        self.shift_every = int(shift_every_tokens)
        self.shift_roll = int(shift_roll) if shift_roll > 0 \
            else max(n_experts // 8, 1)
        self._shift_acc = 0
        self.n_shifts = 0

    def maybe_drift(self) -> None:
        """Slow routing drift (what makes static placements go stale)."""
        if self._rng.random() < self.drift:
            l = self._rng.integers(0, self.L)
            s = self._rng.integers(0, self.S)
            p = self.pref[l, s]
            shift = self._rng.permutation(p) * 0.3 + p * 0.7
            self.pref[l, s] = shift / shift.sum()

    def _advance_shift(self, tokens: int) -> None:
        if self.shift_every <= 0 or tokens <= 0:
            return
        # convex blend toward the rolled hot set, a fraction proportional
        # to the tokens just sampled (rows stay normalized: both operands
        # sum to 1)
        f = min(tokens / self.shift_every, 1.0)
        rolled = np.roll(self.pref, self.shift_roll, axis=2)
        self.pref = (1.0 - f) * self.pref + f * rolled
        self._shift_acc += tokens
        while self._shift_acc >= self.shift_every:
            self._shift_acc -= self.shift_every
            self.n_shifts += 1

    def sample_counts(self, source: int, tokens: int, top_k: int
                      ) -> np.ndarray:
        """(L, E) expected routed counts (+Poisson noise) for one step."""
        lam = self.pref[:, source, :] * (tokens * top_k)
        out = self._rng.poisson(lam).astype(np.int64)
        self._advance_shift(tokens)
        return out
