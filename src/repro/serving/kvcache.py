"""Paged KV-cache accounting (control plane) + slot allocator (real engine).

The block pool is the vLLM-style paged allocator: requests reserve
block_size-token pages; usage fraction is the ``kv_usage`` trace signal and
drives both the KV-protection path in Algorithm 1 and preemption.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


class BlockPool:
    def __init__(self, total_tokens: int, block_size: int = 16):
        self.block_size = block_size
        self.total_blocks = max(total_tokens // block_size, 1)
        self.free_blocks = self.total_blocks
        self._held: Dict[int, int] = {}   # req_id -> blocks held
        # cumulative physical allocations (prefix-sharing benches compare
        # this across sharing on/off runs)
        self.stat_blocks_allocated = 0
        # prefix-sharing telemetry, defined on EVERY pool (zero on plain
        # ones) so cluster aggregation reads them directly instead of
        # getattr-defaulting — a pool that "never shares" and a pool that
        # silently lost the field must not look alike
        self.stat_cow_copies = 0
        self.stat_hit_pages = 0
        self.stat_hit_tokens = 0          # token-granular cache-hit tokens
        self.stat_hit_tokens_page = 0     # the page-aligned part of those

    @staticmethod
    def blocks_for(tokens: int, block_size: int) -> int:
        return -(-max(tokens, 1) // block_size)

    def can_allocate(self, req_id: int, tokens: int) -> bool:
        need = self.blocks_for(tokens, self.block_size) \
            - self._held.get(req_id, 0)
        return need <= self.free_blocks

    def allocate(self, req_id: int, tokens: int) -> bool:
        """Grow req's reservation to cover ``tokens`` total. False if OOM."""
        need = self.blocks_for(tokens, self.block_size) \
            - self._held.get(req_id, 0)
        if need > self.free_blocks:
            return False
        if need > 0:
            self.free_blocks -= need
            self._held[req_id] = self._held.get(req_id, 0) + need
            self.stat_blocks_allocated += need
        return True

    def free(self, req_id: int) -> None:
        self.free_blocks += self._held.pop(req_id, 0)

    @property
    def usage(self) -> float:
        return 1.0 - self.free_blocks / self.total_blocks

    def held_tokens(self, req_id: int) -> int:
        return self._held.get(req_id, 0) * self.block_size


class SlotAllocator:
    """Fixed-slot cache rows for the real (tiny-model) engine: the batched
    decode call uses cache arrays (n_slots, ...) indexed by slot id."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free = list(range(n_slots))[::-1]
        self._of: Dict[int, int] = {}

    def acquire(self, req_id: int) -> Optional[int]:
        if req_id in self._of:
            return self._of[req_id]
        if not self._free:
            return None
        slot = self._free.pop()
        self._of[req_id] = slot
        return slot

    def release(self, req_id: int) -> None:
        slot = self._of.pop(req_id, None)
        if slot is not None:
            self._free.append(slot)

    def slot_of(self, req_id: int) -> Optional[int]:
        return self._of.get(req_id)
