"""Host-memory KV page tier: swap instead of recompute, park cold prefixes.

The pool's only response to KV pressure used to be preempt-and-recompute.
This module adds the other half of the classic trade — "recompute the
prefill phase (compute-heavy) or reload KV from storage (I/O-heavy)" — as
a subsystem where KV state outlives device residency:

* :class:`HostKVTier` is a host-memory page store shared by every engine
  on a node (both planes use the same class, so Algorithm-1 signals
  agree). It holds two kinds of entries: whole-request page sets keyed by
  ``req_id`` (preemption/drain swap-out) and single archived pages keyed
  by an opaque handle (cold radix-indexed prefix pages parked off-device).
  Payloads are opaque to the tier — whatever the engine's ``save_pages``
  callback returns (host numpy copies on the real plane, ``None`` on the
  sim plane, which tracks only the accounting).

* :class:`TieredSharedAllocator` extends ``SharedPagedAllocator`` with
  explicit :meth:`~TieredSharedAllocator.swap_out_request` /
  :meth:`~TieredSharedAllocator.swap_in_request` (fp pages round-trip
  bit-exact through host memory), and *archiving*: when the pool would
  evict a reclaimable cached page, it can instead move the page's bytes
  to the tier and leave the radix node in place pointing at a **negative
  virtual id** — the prefix stays matchable while swapped, and a later
  admission match rematerializes it into a fresh device page without any
  recompute (``_attach_slot``).

Truthful accounting falls out of the design: swapped pages leave the
pool's books entirely, so ``free_blocks``/``kv_usage`` count *device-
resident* pages only — the scheduler's KV-pressure signals never charge
an engine for bytes already off-device. ``swapped_tokens`` is the new
per-engine signal for state parked in the tier.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.serving.paged import SharedPagedAllocator, _RadixNode

# save_pages(page_ids) -> payload; load_pages(payload, page_ids) -> None.
# The allocator never inspects payloads: bit-exactness is the callback
# pair's contract (engine_util/paged_engine gather device pages to host
# numpy and scatter them back).
SavePagesFn = Callable[[List[int]], Any]
LoadPagesFn = Callable[[Any, List[int]], None]


@dataclasses.dataclass(frozen=True)
class SwapRecord:
    """One tier transfer: planner decision record + step-plan op.

    ``kind`` is ``"out"`` (device -> host at preemption/drain) or ``"in"``
    (host -> device at re-admission). Transfers execute synchronously at
    decision time (the pages involved may be recycled within the same
    planning pass — same reason COW copies apply at plan time); the
    records ride :class:`~repro.serving.step_plan.StepPlan` for pricing,
    telemetry and invariant checks.
    """

    kind: str
    req_id: int
    n_pages: int
    tokens: int
    nbytes: int


@dataclasses.dataclass
class _TierEntry:
    payload: Any
    n_pages: int
    tokens: int
    nbytes: int


class HostKVTier:
    """Host-memory page store shared across a node's engines.

    ``capacity_pages=0`` means unbounded (host RAM is the real bound and
    is orders of magnitude larger than device pools); a positive value
    caps resident tier pages so tests can exercise tier-full fallbacks.
    ``page_nbytes`` is the per-page transfer size engines report for
    byte-accounting (it depends on the device page layout and dtype, so
    the engine that owns the arrays sets it).
    """

    def __init__(self, capacity_pages: int = 0, page_nbytes: int = 0):
        self.capacity_pages = capacity_pages
        self.page_nbytes = page_nbytes
        self._requests: Dict[int, _TierEntry] = {}
        self._pages: Dict[int, _TierEntry] = {}
        self._next_handle = 1
        self.stat_out_pages = 0
        self.stat_in_pages = 0
        self.stat_out_bytes = 0
        self.stat_in_bytes = 0
        self.stat_dropped_pages = 0

    # ---- capacity --------------------------------------------------------
    @property
    def pages_used(self) -> int:
        return (sum(e.n_pages for e in self._requests.values())
                + len(self._pages))

    def can_store(self, n_pages: int) -> bool:
        if self.capacity_pages <= 0:
            return True
        return self.pages_used + n_pages <= self.capacity_pages

    @property
    def swapped_tokens(self) -> int:
        """Total tokens of request state resident in the tier (all engines)."""
        return sum(e.tokens for e in self._requests.values())

    # ---- whole-request entries (swap-out / swap-in) ----------------------
    def put_request(self, req_id: int, payload: Any, *, n_pages: int,
                    tokens: int, nbytes: int) -> None:
        assert req_id not in self._requests, "request already swapped"
        self._requests[req_id] = _TierEntry(payload, n_pages, tokens, nbytes)
        self.stat_out_pages += n_pages
        self.stat_out_bytes += nbytes

    def holds_request(self, req_id: int) -> bool:
        return req_id in self._requests

    def peek_request(self, req_id: int) -> Optional[_TierEntry]:
        return self._requests.get(req_id)

    def take_request(self, req_id: int) -> _TierEntry:
        e = self._requests.pop(req_id)
        self.stat_in_pages += e.n_pages
        self.stat_in_bytes += e.nbytes
        return e

    def drop_request(self, req_id: int) -> bool:
        """Discard a swapped request's pages (quarantine/cancel path)."""
        e = self._requests.pop(req_id, None)
        if e is not None:
            self.stat_dropped_pages += e.n_pages
        return e is not None

    # ---- single archived pages (parked prefix pages) ---------------------
    def archive_page(self, payload: Any, nbytes: int) -> int:
        """Store one page; returns a handle >= 1 (allocators index the
        page under the negative of this handle)."""
        h = self._next_handle
        self._next_handle += 1
        self._pages[h] = _TierEntry(payload, 1, 0, nbytes)
        self.stat_out_pages += 1
        self.stat_out_bytes += nbytes
        return h

    def has_page(self, handle: int) -> bool:
        return handle in self._pages

    def take_page(self, handle: int) -> _TierEntry:
        e = self._pages.pop(handle)
        self.stat_in_pages += 1
        self.stat_in_bytes += e.nbytes
        return e

    def drop_page(self, handle: int) -> None:
        if self._pages.pop(handle, None) is not None:
            self.stat_dropped_pages += 1


class TieredSharedAllocator(SharedPagedAllocator):
    """Prefix-sharing allocator with a host tier behind it.

    Three behaviors on top of :class:`SharedPagedAllocator`:

    * **swap-out / swap-in** of whole requests: gather the block table's
      pages to the tier, free the device pages (the request keeps its
      ``prefill_done``/``generated`` progress), then later restore into
      freshly allocated pages — no recompute, bit-exact on fp pages;
    * **archiving**: ``_take_page`` under pressure moves the LRU cached
      page's bytes to the tier instead of discarding them, leaving the
      radix node pointing at a negative virtual id so the prefix stays
      matchable. ``_attach_slot`` rematerializes on match;
    * **truthful books**: swapped and archived pages are *not* counted in
      ``free_blocks``/``kv_usage`` — only device-resident state is.

    Passing ``save_pages=None`` (sim plane) stores ``None`` payloads:
    all the accounting, none of the bytes.
    """

    def __init__(self, n_pages: int, page_size: int = 16, *,
                 tier: HostKVTier,
                 save_pages: Optional[SavePagesFn] = None,
                 load_pages: Optional[LoadPagesFn] = None,
                 archive_prefixes: bool = True):
        super().__init__(n_pages, page_size)
        self.tier = tier
        self._save: SavePagesFn = save_pages or (lambda ids: None)
        self._load: LoadPagesFn = load_pages or (lambda payload, ids: None)
        self.archive_prefixes = archive_prefixes
        # req_id -> tokens swapped out *by this allocator* (the per-engine
        # share of the tier's total; pruned lazily as peers swap them in)
        self._swapped: Dict[int, int] = {}
        self.stat_archived_pages = 0
        self.stat_revived_pages = 0
        self.stat_swapped_out_reqs = 0
        self.stat_swapped_in_reqs = 0

    # ---- request swap ----------------------------------------------------
    def swap_out_request(self, req_id: int, tokens: int) \
            -> Optional[SwapRecord]:
        """Move ``req_id``'s pages to the tier and free them on-device.
        Returns the transfer record, or None when the request holds no
        pages or the tier is full (caller falls back to recompute)."""
        table = self.tables.get(req_id)
        if not table or self.tier.holds_request(req_id):
            return None
        n = len(table)
        if not self.tier.can_store(n):
            return None
        payload = self._save(list(table))
        nbytes = n * self.tier.page_nbytes
        self.tier.put_request(req_id, payload, n_pages=n, tokens=tokens,
                              nbytes=nbytes)
        self.free(req_id)
        self._swapped[req_id] = tokens
        self.stat_swapped_out_reqs += 1
        return SwapRecord("out", req_id, n, tokens, nbytes)

    def swap_in_request(self, req_id: int) -> Optional[SwapRecord]:
        """Restore a swapped request into freshly allocated device pages.
        Returns None (books untouched, entry kept) when the pool cannot
        back the pages — the caller retries later or recomputes."""
        ent = self.tier.peek_request(req_id)
        if ent is None:
            return None
        assert not self.tables.get(req_id), "swap-in over a live table"
        n = ent.n_pages
        if self.force_alloc_fail or n > self.free_blocks:
            return None
        pages = []
        for _ in range(n):
            p = self._take_page()
            self.refcount[p] = 1
            pages.append(p)
        self.tables[req_id] = pages
        self.free_blocks -= n
        self._held[req_id] = n
        self.stat_blocks_allocated += n
        ent = self.tier.take_request(req_id)
        self._load(ent.payload, pages)
        self._swapped.pop(req_id, None)
        self.stat_swapped_in_reqs += 1
        return SwapRecord("in", req_id, n, ent.tokens, ent.nbytes)

    def holds_swapped(self, req_id: int) -> bool:
        return self.tier.holds_request(req_id)

    def drop_swapped(self, req_id: int) -> bool:
        """Discard a swapped request's tier entry (quarantine/cancel)."""
        self._swapped.pop(req_id, None)
        return self.tier.drop_request(req_id)

    @property
    def swapped_tokens(self) -> int:
        """Tokens this engine swapped out that are still in the tier."""
        stale = [rid for rid in self._swapped
                 if not self.tier.holds_request(rid)]
        for rid in stale:
            del self._swapped[rid]
        return sum(self._swapped.values())

    # ---- archiving (cold prefix pages park off-device) -------------------
    def _take_page(self) -> int:
        if self._free_ids:
            return self._free_ids.pop()
        if self.archive_prefixes and self.tier.can_store(1):
            # move the LRU cached page's bytes to the tier instead of
            # discarding them: the radix node stays, repointed at a
            # negative virtual id, so the prefix remains matchable and a
            # later hit rematerializes it without recompute
            for p in self._cached:                # insertion order == LRU
                node = self._page_node[p]
                payload = self._save([p])
                h = self.tier.archive_page(payload,
                                           nbytes=self.tier.page_nbytes)
                del self._cached[p]
                del self._page_node[p]
                node.page = -h
                self._page_node[-h] = node
                self.stat_archived_pages += 1
                return p
        return super()._take_page()

    def _evict(self, node: _RadixNode) -> None:
        """Eviction must also drop the tier entries of any archived
        (virtual-id) pages in the doomed subtree, or host capacity leaks."""
        virt, stack = [], [node]
        while stack:
            n = stack.pop()
            stack.extend(n.children)
            if n.page < 0:
                virt.append(-n.page)
        super()._evict(node)
        for h in virt:
            self.tier.drop_page(h)

    def _attach_slot(self, node: _RadixNode) -> Optional[int]:
        """Attach one matched slot, rematerializing archived pages.

        Rematerialization calls ``_take_page``, which may archive or
        evict *other* cached pages — including nodes memoized for later
        slots of the same match. The identity check guards against that:
        a node no longer indexed under its page was recycled mid-match,
        so the match truncates (``None``) instead of attaching stale or
        foreign content. Earlier slots are safe — once attached their
        refcount is >= 1, so they are neither cached nor evictable.
        """
        p = node.page
        if p >= 0:
            if self._page_node.get(p) is not node:
                return None           # evicted by an earlier slot's revive
            return super()._attach_slot(node)
        if self._page_node.get(p) is not node:
            return None
        if self.force_alloc_fail or self.free_blocks == 0:
            return None
        phys = self._take_page()
        self.refcount[phys] = 1
        self.free_blocks -= 1
        ent = self.tier.take_page(-p)
        self._load(ent.payload, [phys])
        del self._page_node[p]
        node.page = phys
        self._page_node[phys] = node
        self.stat_revived_pages += 1
        return phys

    # ---- teardown --------------------------------------------------------
    def drop_index(self) -> None:
        """Evict the whole radix index, dropping archived tier handles.

        Crash/reset teardown: the index dies with the pool, so parked
        prefix pages become unreachable and must not leak host capacity.
        Request-level tier entries are *kept* — their payloads were
        copied to host before the crash and re-attach on any engine
        sharing the tier."""
        for c in list(self._root.children):
            cached_own = c.page in self._cached
            self._evict(c)         # _evict leaves the root page to caller
            if cached_own:
                self._free_ids.append(c.page)

    # ---- invariants ------------------------------------------------------
    def check_invariants(self) -> None:
        super().check_invariants()
        for vid, node in self._page_node.items():
            if vid < 0:
                assert self.tier.has_page(-vid), \
                    "archived page lost its tier entry"
                assert node.page == vid
        for rid in self.tables:
            assert not self.tier.holds_request(rid), \
                "request both device-resident and swapped"
        for rid in self._swapped:
            assert rid not in self.tables
