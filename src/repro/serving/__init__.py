from repro.serving.costmodel import CostModelConfig, EngineCostModel
from repro.serving.engine import DPEngine, EngineConfig
from repro.serving.kvcache import BlockPool, SlotAllocator
from repro.serving.request import Request, RequestState
from repro.serving.routing_sim import SourceExpertTraffic
from repro.serving.simulator import (PAPER_SYSTEMS, SimResult, SystemConfig,
                                     simulate)

__all__ = ["CostModelConfig", "EngineCostModel", "DPEngine", "EngineConfig",
           "BlockPool", "SlotAllocator", "Request", "RequestState",
           "SourceExpertTraffic", "PAPER_SYSTEMS", "SimResult",
           "SystemConfig", "simulate"]
