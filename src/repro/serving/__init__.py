from repro.serving.costmodel import (CostModelConfig, EngineCostModel,
                                     SwapCostConfig, SwapCostModel)
from repro.serving.engine import DPEngine, EngineConfig
from repro.serving.kv_tier import (HostKVTier, SwapRecord,
                                   TieredSharedAllocator)
from repro.serving.kvcache import BlockPool, SlotAllocator
from repro.serving.paged import (GARBAGE_PAGE, PagedBlockAllocator,
                                 SharedPagedAllocator)
from repro.serving.paged_engine import (PagedEngineConfig, PagedModelRunner,
                                        PagedRealEngine)
from repro.serving.real_cluster import RealClusterConfig, serve_real_cluster
from repro.serving.request import Request, RequestState
from repro.serving.routing_sim import SourceExpertTraffic
from repro.serving.simulator import (PAPER_SYSTEMS, SimResult, SystemConfig,
                                     simulate)
from repro.serving.step_plan import (PlannerConfig, PrefillLane, StepPlan,
                                     StepPlanner, check_plan_invariants)

__all__ = ["CostModelConfig", "EngineCostModel", "SwapCostConfig",
           "SwapCostModel", "DPEngine", "EngineConfig",
           "HostKVTier", "SwapRecord", "TieredSharedAllocator",
           "BlockPool", "SlotAllocator", "GARBAGE_PAGE",
           "PagedBlockAllocator", "SharedPagedAllocator",
           "PagedEngineConfig", "PagedModelRunner",
           "PagedRealEngine", "RealClusterConfig", "serve_real_cluster",
           "Request", "RequestState", "SourceExpertTraffic", "PAPER_SYSTEMS",
           "SimResult", "SystemConfig", "simulate",
           "PlannerConfig", "PrefillLane", "StepPlan", "StepPlanner",
           "check_plan_invariants"]
