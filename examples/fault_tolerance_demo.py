"""Fault tolerance walkthrough: engine failure, re-dispatch, checkpoint
restart, elastic scale-up — control-plane mechanics on synthetic traces,
then the real thing: a paged engine crashes mid-decode, its requests are
exported with emitted tokens folded into resume prompts, and the restarted
engine continues the streams bit-exact.

PYTHONPATH=src python examples/fault_tolerance_demo.py
(full cluster chaos run: python -m repro.launch.serve --real --paged --chaos)
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import EngineTrace, GimbalScheduler, TraceTable
from repro.ft import (ElasticController, EngineHealthMonitor, HealthConfig,
                      restore_checkpoint, save_checkpoint)
from repro.models import build_model


def main():
    # ---- engine failure + re-dispatch
    table = TraceTable([0, 1, 2])
    sched = GimbalScheduler(table)
    for e in range(3):
        table.report(EngineTrace(e), now=0.0)
    moved = []
    mon = EngineHealthMonitor(table, sched, HealthConfig(trace_timeout_s=1.0),
                              redispatch=lambda e: moved.append(e) or 3)
    table.report(EngineTrace(0), now=5.0)
    table.report(EngineTrace(1), now=5.0)   # engine 2 goes silent
    down = mon.check(now=5.0)
    print(f"health: engines down = {down}, requests re-dispatched from "
          f"{moved}")
    picks = {sched.select_engine(100, 5.0) for _ in range(6)}
    print(f"dispatch now avoids engine 2: picks = {sorted(picks)}")
    table.report(EngineTrace(2), now=6.0)   # engine recovers
    mon.check(now=6.0)
    print(f"after rejoin: {sorted({sched.select_engine(100, 6.0) for _ in range(6)})}")

    # ---- checkpoint / restart
    cfg = get_smoke_config("qwen3-8b")
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save_checkpoint(path, params, step=123)
        restored = restore_checkpoint(path, params)
        same = all(bool((np.asarray(a) == np.asarray(b)).all())
                   for a, b in zip(jax.tree.leaves(params),
                                   jax.tree.leaves(restored)))
        print(f"checkpoint roundtrip exact: {same}")

    # ---- elastic scale-up/down
    ec = ElasticController(table, sched)
    ec.scale_up(3, now=7.0)
    print(f"scaled up: engines = {table.engine_ids} "
          f"(new engine covered by ordered dispatch until first trace)")
    ec.scale_down(1, now=8.0, drain=lambda e: 2)
    print(f"scaled down engine 1: engines = {table.engine_ids}")
    print(f"elastic log: {ec.log}")

    # ---- real plane: crash a paged engine mid-decode, resume bit-exact
    from repro.configs.base import reduced
    from repro.serving import (PagedEngineConfig, PagedRealEngine, Request)
    cfg = reduced(get_smoke_config("qwen3-moe-30b-a3b"), n_layers=2)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    ecfg = PagedEngineConfig(page_size=8, n_pages=32, max_blocks_per_req=8,
                             max_batch=4, token_budget=16,
                             chunk_buckets=(8, 16), attn_backend="xla")
    rng = np.random.default_rng(3)

    def mk():
        return Request(req_id=0, prompt_len=12, max_new_tokens=6,
                       arrival_time=0.0,
                       prompt_tokens=np.random.default_rng(3).integers(
                           0, cfg.vocab_size, 12).tolist())

    def drive(e, t=0.0):
        while e.has_work:
            e.step(t)
            t += 0.01

    eng = PagedRealEngine(0, cfg, params, ecfg, n_sources=1)
    ref = mk()
    eng.enqueue(ref, 0.0)
    drive(eng)
    print(f"\nreal plane — uninterrupted stream: {ref.output_tokens}")

    req = mk()
    eng.enqueue(req, 0.0)
    for i in range(4):                      # partway through decode
        eng.step(0.01 * i)
    exported = eng.fail(0.04)               # KV pool lost
    print(f"crash mid-decode: exported {len(exported)} request(s), "
          f"emitted so far {req.resume_output}, resume prompt "
          f"{req.prompt_len} tokens (= 12 prompt + emitted)")
    eng.restart()
    eng.enqueue(req, 0.1)
    drive(eng, 0.1)
    print(f"after restart+resume:        {req.full_output_tokens}")
    print(f"bit-exact continuation: "
          f"{req.full_output_tokens == ref.output_tokens}")
    assert req.full_output_tokens == ref.output_tokens


if __name__ == "__main__":
    main()
