"""Fault tolerance walkthrough: engine failure, re-dispatch, checkpoint
restart, elastic scale-up.

PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import EngineTrace, GimbalScheduler, TraceTable
from repro.ft import (ElasticController, EngineHealthMonitor, HealthConfig,
                      restore_checkpoint, save_checkpoint)
from repro.models import build_model


def main():
    # ---- engine failure + re-dispatch
    table = TraceTable([0, 1, 2])
    sched = GimbalScheduler(table)
    for e in range(3):
        table.report(EngineTrace(e), now=0.0)
    moved = []
    mon = EngineHealthMonitor(table, sched, HealthConfig(trace_timeout_s=1.0),
                              redispatch=lambda e: moved.append(e) or 3)
    table.report(EngineTrace(0), now=5.0)
    table.report(EngineTrace(1), now=5.0)   # engine 2 goes silent
    down = mon.check(now=5.0)
    print(f"health: engines down = {down}, requests re-dispatched from "
          f"{moved}")
    picks = {sched.select_engine(100, 5.0) for _ in range(6)}
    print(f"dispatch now avoids engine 2: picks = {sorted(picks)}")
    table.report(EngineTrace(2), now=6.0)   # engine recovers
    mon.check(now=6.0)
    print(f"after rejoin: {sorted({sched.select_engine(100, 6.0) for _ in range(6)})}")

    # ---- checkpoint / restart
    cfg = get_smoke_config("qwen3-8b")
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save_checkpoint(path, params, step=123)
        restored = restore_checkpoint(path, params)
        same = all(bool((np.asarray(a) == np.asarray(b)).all())
                   for a, b in zip(jax.tree.leaves(params),
                                   jax.tree.leaves(restored)))
        print(f"checkpoint roundtrip exact: {same}")

    # ---- elastic scale-up/down
    ec = ElasticController(table, sched)
    ec.scale_up(3, now=7.0)
    print(f"scaled up: engines = {table.engine_ids} "
          f"(new engine covered by ordered dispatch until first trace)")
    ec.scale_down(1, now=8.0, drain=lambda e: 2)
    print(f"scaled down engine 1: engines = {table.engine_ids}")
    print(f"elastic log: {ec.log}")


if __name__ == "__main__":
    main()
