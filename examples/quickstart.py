"""Quickstart: train a tiny MoE LM for a few steps, then serve one request.

PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train import AdamWConfig, make_train_state, make_train_step


def main():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    fns = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = fns.init(key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} (smoke) — {n_params/1e6:.2f}M params, "
          f"{cfg.moe.n_experts} experts top-{cfg.moe.top_k}")

    state = make_train_state(params, AdamWConfig(lr=1e-3))
    step = jax.jit(make_train_step(
        lambda p, b: fns.loss(p, b), AdamWConfig(lr=1e-3)))

    B, S = 8, 32
    for i in range(30):
        k = jax.random.fold_in(key, i)
        toks = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        t0 = time.time()
        state, metrics = step(state, batch)
        if i % 5 == 0:
            print(f"step {i:3d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"({(time.time()-t0)*1000:.0f} ms)")

    # serve one request with the trained params
    cache = fns.init_cache(1, 64)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    logits, cache, _ = jax.jit(fns.prefill)(
        state.params, {"tokens": prompt,
                       "lengths": jnp.asarray([8], jnp.int32)}, cache)
    out = [int(jnp.argmax(logits[0]))]
    lengths = jnp.asarray([8], jnp.int32)
    for _ in range(8):
        logits, cache, _ = jax.jit(fns.decode)(
            state.params, jnp.asarray([out[-1]], jnp.int32), cache, lengths)
        out.append(int(jnp.argmax(logits[0])))
        lengths = lengths + 1
    print("generated token ids:", out)


if __name__ == "__main__":
    main()
