"""End-to-end Gimbal serving driver (the paper's system, real data plane).

Two DP engines serve a real tiny MoE model with batched requests. The full
coordinated loop runs: pressure-aware dispatch (Algorithm 1), SJF+aging
local queues (Algorithm 2), REAL source-DP-to-expert statistics from the
router, source-aware expert placement with migration, and MoE-pressure
feedback into dispatch.

PYTHONPATH=src python examples/serve_moe.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import (CoordinatorConfig, GimbalCoordinator,
                        GimbalScheduler, TraceTable)
from repro.models import build_model
from repro.models.transformer import (identity_placement,
                                      migrate_params_for_placement)
from repro.serving.real_engine import RealModelEngine
from repro.serving.request import Request, RequestState
from repro.workloads import generate_trace


def main():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    fns = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = fns.init(key)

    n_engines, n_ranks = 2, 4
    engines = [RealModelEngine(i, cfg, params, max_slots=4, max_len=96,
                               n_sources=n_engines)
               for i in range(n_engines)]
    table = TraceTable(range(n_engines))
    sched = GimbalScheduler(table)
    coord = GimbalCoordinator(
        cfg.n_moe_layers, cfg.moe.n_experts, n_ranks, n_engines,
        cfg=CoordinatorConfig(window_tokens=400))

    reqs = generate_trace("two_end", 12, rps=50.0, seed=0, mean_output=12)
    rng = np.random.default_rng(0)
    for r in reqs:
        r.prompt_len = min(r.prompt_len % 24 + 4, 48)
        r.max_new_tokens = min(r.max_new_tokens, 16)
        r.prompt_tokens = rng.integers(
            0, cfg.vocab_size, r.prompt_len).tolist()

    t0 = time.time()
    pending = list(reqs)
    now = 0.0
    migrations = 0
    cur_perms = np.asarray(identity_placement(cfg))
    while pending or any(e.has_work for e in engines):
        now = time.time() - t0
        # dispatch arrivals due by now (Algorithm 1 against live traces)
        for r in list(pending):
            if r.arrival_time <= now * 50:     # compress sim time
                eid = sched.select_engine(r.prompt_len, now)
                engines[eid].enqueue(r, now)
                pending.remove(r)
        for e in engines:
            e.step(now)
            table.report(e.trace(now), now=now)
            sched.on_trace_refresh(e.engine_id)
            B, A = e.window_stats()
            if B is not None:
                coord.profiler.record_step(B, A, n_tokens=int(B.sum())
                                           // max(cfg.n_moe_layers, 1)
                                           // max(cfg.moe.top_k, 1))
        migrated, dur = coord.maybe_rebalance(now)
        if migrated:
            migrations += 1
            perms = np.asarray(coord.placement.permutations())
            # adopting a placement MOVES the weights: permute the stacked
            # expert params alongside the routing table
            params = migrate_params_for_placement(params, cfg,
                                                  cur_perms, perms)
            cur_perms = perms
            for e in engines:
                e.params = params
                e.placement = perms
                e.moe_pressure = coord.engine_moe_pressure(e.engine_id)
            print(f"[t={now:5.1f}s] expert migration #{migrations} "
                  f"({coord.migration_log[-1]['moves']} moves, "
                  f"{dur:.2f}s modeled)")

    done = [r for r in reqs if r.state is RequestState.FINISHED]
    print(f"\nserved {len(done)}/{len(reqs)} requests on {n_engines} engines "
          f"in {time.time()-t0:.1f}s wall")
    print(f"dispatch decisions: {sched.decisions}")
    print(f"expert migrations: {migrations} "
          f"({coord.placement.n_migrations} expert moves)")
    by_engine = {e.engine_id: sum(1 for r in done
                                  if r.engine_id == e.engine_id)
                 for e in engines}
    print(f"requests per engine: {by_engine}")
    B, A = coord.profiler.snapshot(reset=False)
    if A.sum() > 0:
        print(f"cross-DP traffic fraction under final placement: "
              f"{coord.cross_dp_fraction(A):.1%}")


if __name__ == "__main__":
    main()
