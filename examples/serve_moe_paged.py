"""Gimbal over the paged real data plane (the production-shaped runtime).

Two PagedRealEngine DP replicas serve a tiny MoE model end to end:
physical paged KV with block tables, chunked prefill under a per-step token
budget, batched block-table decode, preemption that reclaims pages and
recomputes, and truthful trace signals feeding Algorithm 1. The Gimbal
coordinator consumes REAL router statistics and migrates experts live.

PYTHONPATH=src python examples/serve_moe_paged.py
"""
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving import (PagedEngineConfig, PagedModelRunner,
                           PagedRealEngine, RealClusterConfig, Request,
                           RequestState, serve_real_cluster)


def main():
    import jax
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    ecfg = PagedEngineConfig(page_size=8, n_pages=32, max_blocks_per_req=8,
                             max_batch=4, token_budget=16,
                             chunk_buckets=(8, 16))
    runner = PagedModelRunner(cfg, params, ecfg, n_sources=2)
    engines = [PagedRealEngine(i, cfg, params, ecfg, runner=runner,
                               n_sources=2) for i in range(2)]

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(12):
        plen = int(rng.integers(8, 40))
        reqs.append(Request(
            req_id=i, prompt_len=plen,
            max_new_tokens=int(rng.integers(4, 10)),
            arrival_time=0.05 * i,
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen).tolist()))

    res = serve_real_cluster(
        reqs, engines, cluster_cfg=RealClusterConfig(window_tokens=300))

    done = [r for r in reqs if r.state is RequestState.FINISHED
            and not r.error]
    print(f"served {len(done)}/{len(reqs)} requests on {len(engines)} "
          f"paged engines ({res.signals['rounds']} cluster rounds)")
    print(f"dispatch decisions: {res.signals['decisions']}")
    print(f"preemptions: {res.signals['preemptions']}  "
          f"stalls: {res.signals['stalled']}  "
          f"kv peak: {res.signals['kv_peak']:.1%}")
    print(f"expert migrations: {res.signals['migrations']} "
          f"({res.signals['expert_moves']} expert moves)")
    print(f"requests per engine: {res.signals['per_engine']}")
    print(f"mean ttft {res.mean_ttft:.2f}s  mean e2e {res.mean_e2e:.2f}s "
          f"(virtual time)")
    for e in engines:
        e.pool.check_invariants()


if __name__ == "__main__":
    main()
