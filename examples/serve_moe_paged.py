"""Gimbal over the paged real data plane (the production-shaped runtime).

Two PagedRealEngine DP replicas serve a tiny MoE model end to end:
physical paged KV with block tables, chunked prefill under a per-step token
budget, batched block-table decode, preemption that reclaims pages and
recomputes, and truthful trace signals feeding Algorithm 1. The Gimbal
coordinator consumes REAL router statistics and migrates experts live.

With ``--shared-prefix`` every request carries a common 24-token system
prompt and the engines run the ``SharedPagedAllocator`` (ref-counted pages
+ radix-tree token-granular prefix cache + copy-on-write); the run is
repeated with sharing off to show pages saved, prefill skipped and the
TTFT delta — with bit-identical outputs. Under sharing the engines also
ship radix prefix summaries on their traces, so Algorithm 1's
prefix-affinity credit routes repeated prefixes to the engine already
holding them (the ``affinity`` dispatch count in the report).

With ``--chaos`` the same stream is served twice — fault-free, then under
a deterministic :class:`~repro.ft.faults.FaultPlan` that crashes engine 1
mid-run (KV pool lost) and recovers it later: the health monitor fences
the silent engine, its residents re-dispatch with emitted tokens folded
into resume prompts, and the run proves every request completes bit-exact
vs the fault-free pass.

PYTHONPATH=src python examples/serve_moe_paged.py [--shared-prefix|--chaos]
"""
import dataclasses

import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving import (PagedEngineConfig, PagedModelRunner,
                           PagedRealEngine, RealClusterConfig, Request,
                           RequestState, serve_real_cluster)


def _requests(cfg, rng, n=12, system=None):
    reqs = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(8, 40))).tolist()
        if system is not None:
            toks = list(system) + toks[:12]
        reqs.append(Request(
            req_id=i, prompt_len=len(toks),
            max_new_tokens=int(rng.integers(4, 10)),
            arrival_time=0.05 * i, prompt_tokens=toks))
    return reqs


def _serve(cfg, params, runner, ecfg, reqs, **cluster_kw):
    engines = [PagedRealEngine(i, cfg, params, ecfg, runner=runner,
                               n_sources=2) for i in range(2)]
    res = serve_real_cluster(
        reqs, engines, cluster_cfg=RealClusterConfig(window_tokens=300,
                                                     **cluster_kw))
    for e in engines:
        e.pool.check_invariants()
    return res, engines


def _report(reqs, engines, res):
    done = [r for r in reqs if r.state is RequestState.FINISHED
            and not r.error]
    print(f"served {len(done)}/{len(reqs)} requests on {len(engines)} "
          f"paged engines ({res.signals['rounds']} cluster rounds)")
    print(f"dispatch decisions: {res.signals['decisions']}")
    print(f"prefill dispatches: {res.signals['prefill_dispatches']} "
          f"(avg {res.signals['prefill_lanes_per_dispatch']:.2f} "
          f"lanes fused per dispatch)")
    print(f"preemptions: {res.signals['preemptions']}  "
          f"stalls: {res.signals['stalled']}  "
          f"kv peak: {res.signals['kv_peak']:.1%}")
    print(f"expert migrations: {res.signals['migrations']} "
          f"({res.signals['expert_moves']} expert moves)")
    print(f"requests per engine: {res.signals['per_engine']}")
    print(f"mean ttft {res.mean_ttft:.2f}s  mean e2e {res.mean_e2e:.2f}s "
          f"(virtual time)")


def _chaos(cfg, params, runner, ecfg):
    """Crash engine 1 mid-run, recover it, prove nothing was lost."""
    from repro.ft import FaultEvent, FaultPlan
    from repro.ft.health import HealthConfig

    mk = lambda: _requests(cfg, np.random.default_rng(0))
    res0, _ = _serve(cfg, params, runner, ecfg, base := mk())
    want = {r.req_id: r.output_tokens for r in base}

    plan = FaultPlan(events=(FaultEvent("crash", 1, 10),
                             FaultEvent("recover", 1, 22)))
    res, engines = _serve(
        cfg, params, runner, ecfg, reqs := mk(), fault_plan=plan,
        health_cfg=HealthConfig(trace_timeout_s=0.3))

    print("== chaos: engine 1 crashes at round 10, recovers at 22 ==")
    _report(reqs, engines, res)
    print(f"health events: {res.signals['health_events']}")
    print(f"engine failures: {res.signals['n_failures']}  "
          f"requests recovered: {res.signals['recovered_requests']}  "
          f"recompute tokens: {res.signals['recovery_recompute_tokens']}")
    exact = all(r.full_output_tokens == want[r.req_id] for r in reqs)
    lost = [r.req_id for r in reqs
            if r.state is not RequestState.FINISHED or r.error]
    print(f"bit-exact vs fault-free: {exact}  lost/errored: {lost}")
    assert exact and not lost


def main(shared_prefix: bool = False, chaos: bool = False):
    import jax
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    ecfg = PagedEngineConfig(page_size=8, n_pages=32, max_blocks_per_req=8,
                             max_batch=4, token_budget=16,
                             chunk_buckets=(8, 16))
    runner = PagedModelRunner(cfg, params, ecfg, n_sources=2)

    if chaos:
        _chaos(cfg, params, runner, ecfg)
        return
    if not shared_prefix:
        reqs = _requests(cfg, np.random.default_rng(0))
        res, engines = _serve(cfg, params, runner, ecfg, reqs)
        _report(reqs, engines, res)
        return

    # shared-system-prompt workload, sharing on vs off on the same stream
    system = np.random.default_rng(7).integers(0, cfg.vocab_size, 24)
    mk = lambda: _requests(cfg, np.random.default_rng(0), system=system)
    res_off, eng_off = _serve(cfg, params, runner, ecfg, reqs_off := mk())
    shared_cfg = dataclasses.replace(ecfg, prefix_sharing=True)
    res_on, eng_on = _serve(cfg, params, runner, shared_cfg,
                            reqs_on := mk())

    print("== sharing OFF ==")
    _report(reqs_off, eng_off, res_off)
    print("== sharing ON (ref-counted prefix cache + COW) ==")
    _report(reqs_on, eng_on, res_on)
    identical = all(a.output_tokens == b.output_tokens
                    for a, b in zip(reqs_off, reqs_on))
    saved = res_off.signals["pages_allocated"] \
        - res_on.signals["pages_allocated"]
    print(f"bit-identical outputs: {identical}")
    print(f"physical pages saved: {saved} "
          f"({res_on.signals['pages_allocated']} vs "
          f"{res_off.signals['pages_allocated']})")
    print(f"prefill tokens skipped via cache: "
          f"{res_on.signals['prefix_hit_tokens']}  "
          f"cow copies: {res_on.signals['cow_copies']}")
    print(f"affinity dispatches (prefix-holding engine picked): "
          f"{res_on.signals['decisions']['affinity_path']}  "
          f"per-engine hits: {res_on.signals['per_engine_prefix_hits']}")
    assert identical and saved > 0


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-system-prompt workload with the "
                         "prefix-sharing allocator, vs a no-sharing run")
    ap.add_argument("--chaos", action="store_true",
                    help="crash engine 1 mid-run and recover it: fence, "
                         "re-dispatch, rejoin — bit-exact vs fault-free")
    _a = ap.parse_args()
    main(shared_prefix=_a.shared_prefix, chaos=_a.chaos)
