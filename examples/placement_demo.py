"""Source-aware expert placement walkthrough (paper §5 + Fig. 6).

Collects a routing window, solves placement three ways — EPLB-style
load-only, Gimbal greedy, and the offline MINLP reference — then shows the
objective decomposition, the migration plan, and the (beta, gamma)
calibration.

PYTHONPATH=src python examples/placement_demo.py
"""
import numpy as np

from repro.core import (PlacementConfig, calibrate,
                        default_distance_matrix, greedy_layer_placement,
                        layer_objective, solve_reference)
from repro.serving.routing_sim import SourceExpertTraffic


def main():
    L, E, S, G = 4, 32, 2, 4
    rng = np.random.default_rng(0)
    tr = SourceExpertTraffic(L, E, S, seed=0)
    A = rng.poisson(tr.pref * 5000).astype(np.float64)     # (L, S, E)
    B = A.sum(axis=1)
    D = default_distance_matrix(S, G)
    prev = np.stack([np.arange(E) // (E // G)] * L)
    cfg = PlacementConfig(mig_cost_tokens=500.0)

    print(f"window: {int(B.sum())} routed entries, {L} layers x {E} experts"
          f" on {G} EP ranks / {S} DP sources\n")
    print(f"{'policy':<18}{'C_load':>12}{'C_comm':>12}{'C_mig':>10}"
          f"{'moves':>8}")
    for name, solver in (
        ("incumbent", lambda l: prev[l]),
        ("eplb(load-only)", lambda l: greedy_layer_placement(
            B[l], np.zeros_like(A[l]), D, prev[l],
            PlacementConfig(alpha=0.0, beta=1.0, gamma=0.0))),
        ("gimbal greedy", lambda l: greedy_layer_placement(
            B[l], A[l], D, prev[l], cfg)),
    ):
        cl = cc = cm = moves = 0.0
        for l in range(L):
            a = solver(l)
            o = layer_objective(a, B[l], A[l], D, prev[l], cfg)
            cl, cc, cm = cl + o[0], cc + o[1], cm + o[2]
            moves += int(np.sum(a != prev[l]))
        print(f"{name:<18}{cl:12.3e}{cc:12.3e}{cm:10.0f}{moves:8.0f}")

    ref = solve_reference(B, A, D, prev, cfg)
    cl = cc = cm = 0.0
    for l in range(L):
        o = layer_objective(ref[l], B[l], A[l], D, prev[l], cfg)
        cl, cc, cm = cl + o[0], cc + o[1], cm + o[2]
    print(f"{'MINLP reference':<18}{cl:12.3e}{cc:12.3e}{cm:10.0f}")

    res = calibrate(B, A, D, prev, ref_cfg=cfg)
    print(f"\ncalibration: (alpha, beta, gamma) = (1.0, {res.beta}, "
          f"{res.gamma}) — agreement {res.agreement:.1%} "
          f"(paper >= 80%), comm excess {res.comm_excess:+.2%}")


if __name__ == "__main__":
    main()
